//! Property-based invariants over the coordinator: for randomized traces
//! and every algorithm family, the simulation must preserve memory
//! capacity, yield bounds, virtual-time conservation, and event accounting.
//! (In-repo `forall` helper replaces proptest — see rust/src/util/check.rs.)

use dfrs::alloc::RustSolver;
use dfrs::sched::registry::make_policy;
use dfrs::sim::{run, JobState, SimConfig, SimResult};
use dfrs::util::check::forall;
use dfrs::util::rng::Rng;
use dfrs::workload::{Job, Trace};

/// Random small trace with adversarial shapes (tiny + huge jobs, bursts).
fn random_trace(rng: &mut Rng) -> Trace {
    let nodes = 2 + rng.below(10) as usize;
    let n_jobs = 3 + rng.below(25) as usize;
    let mut t = 0.0;
    let jobs = (0..n_jobs)
        .map(|id| {
            t += if rng.chance(0.3) { 0.0 } else { rng.exponential(400.0) };
            Job {
                id: id as u32,
                submit: t,
                tasks: 1 + rng.below(nodes as u64 / 2 + 1) as u32,
                cpu_need: [0.25, 0.5, 1.0][rng.below(3) as usize],
                mem: 0.1 * (1 + rng.below(8)) as f64,
                proc_time: if rng.chance(0.2) {
                    rng.range(1.0, 10.0)
                } else {
                    rng.range(60.0, 20_000.0)
                },
            }
        })
        .collect();
    Trace { jobs, nodes, cores_per_node: 4, node_mem_gb: 4.0 }
}

fn check_result(alg: &str, _trace: &Trace, r: &SimResult) -> Result<(), String> {
    // 1. Completion: every job done, completion after submit.
    for j in &r.jobs {
        if !matches!(j.state, JobState::Done) {
            return Err(format!("{alg}: job {} not done", j.spec.id));
        }
        let c = j.completion.unwrap();
        if c < j.spec.submit - 1e-9 {
            return Err(format!("{alg}: job {} completes before submit", j.spec.id));
        }
        // 2. Work conservation: virtual time ≈ processing time at completion.
        let tol = 1e-3 * j.spec.proc_time.max(1.0);
        if (j.vt - j.spec.proc_time).abs() > tol {
            return Err(format!(
                "{alg}: job {} vt {} != p {}",
                j.spec.id, j.vt, j.spec.proc_time
            ));
        }
        // 3. No job finishes faster than dedicated speed.
        if c - j.spec.submit < j.spec.proc_time * (1.0 - 1e-6) {
            return Err(format!("{alg}: job {} ran faster than dedicated", j.spec.id));
        }
    }
    // 4. Stretch sanity.
    if r.max_stretch < 1.0 - 1e-9 || !r.max_stretch.is_finite() {
        return Err(format!("{alg}: bad max stretch {}", r.max_stretch));
    }
    if r.avg_stretch > r.max_stretch + 1e-9 {
        return Err(format!("{alg}: avg > max stretch"));
    }
    // 5. Accounting sanity.
    if r.gb_moved < 0.0 || r.underutil_area < -1e-6 {
        return Err(format!("{alg}: negative accounting"));
    }
    let migs: u32 = r.jobs.iter().map(|j| j.migrations).sum();
    let pres: u32 = r.jobs.iter().map(|j| j.preemptions).sum();
    if migs as u64 != r.migrations || pres as u64 != r.preemptions {
        return Err(format!("{alg}: per-job counters disagree with totals"));
    }
    Ok(())
}

fn prop_for(alg: &'static str, seed: u64, cases: usize) {
    forall(seed, cases, random_trace, |trace| {
        let mut p = make_policy(alg, 600.0).map_err(|e| e.to_string())?;
        let r = run(trace, p.as_mut(), SimConfig::default(), Box::new(RustSolver));
        check_result(alg, trace, &r)
    });
}

#[test]
fn invariants_easy() {
    prop_for("EASY", 100, 30);
}

#[test]
fn invariants_fcfs() {
    prop_for("FCFS", 101, 30);
}

#[test]
fn invariants_greedy_star() {
    prop_for("Greedy */OPT=MIN", 102, 30);
}

#[test]
fn invariants_greedyp_star() {
    prop_for("GreedyP */OPT=MIN", 103, 30);
}

#[test]
fn invariants_greedypm_star_per_minvt() {
    prop_for("GreedyPM */per/OPT=MIN/MINVT=600", 104, 25);
}

#[test]
fn invariants_greedyp_per_avg() {
    prop_for("GreedyP/per/OPT=AVG", 105, 20);
}

#[test]
fn invariants_mcb8_star() {
    prop_for("MCB8 */OPT=MIN/MINVT=600", 106, 20);
}

#[test]
fn invariants_per_only() {
    prop_for("/per/OPT=MIN", 107, 20);
}

#[test]
fn invariants_stretch_per() {
    prop_for("/stretch-per/OPT=MAX/MINVT=600", 108, 20);
}

/// The Theorem-1 bound must lower-bound every policy's max bounded stretch
/// on arbitrary traces (the clairvoyant relaxation can only be better).
#[test]
fn bound_is_a_true_lower_bound_across_policies() {
    forall(200, 12, random_trace, |trace| {
        let b = dfrs::bound::max_stretch_lower_bound(trace, 10.0, 1e-3);
        if b < 1.0 - 1e-9 {
            return Err(format!("bound {b} below 1"));
        }
        for alg in ["FCFS", "EASY", "GreedyP */OPT=MIN", "GreedyPM */per/OPT=MIN/MINVT=600"] {
            let mut p = make_policy(alg, 600.0).map_err(|e| e.to_string())?;
            let r = run(trace, p.as_mut(), SimConfig::default(), Box::new(RustSolver));
            if r.max_stretch < b * (1.0 - 1e-6) {
                return Err(format!(
                    "{alg} achieved stretch {} below the bound {b}",
                    r.max_stretch
                ));
            }
        }
        Ok(())
    });
}

/// Failure injection: traces built to poke corner cases.
#[test]
fn corner_simultaneous_submissions() {
    let jobs: Vec<Job> = (0..8)
        .map(|id| Job {
            id,
            submit: 0.0,
            tasks: 2,
            cpu_need: 1.0,
            mem: 0.4,
            proc_time: 100.0,
        })
        .collect();
    let trace = Trace { jobs, nodes: 4, cores_per_node: 4, node_mem_gb: 4.0 };
    for alg in ["EASY", "GreedyP */OPT=MIN", "MCB8 */OPT=MIN/MINVT=600"] {
        let mut p = make_policy(alg, 600.0).unwrap();
        let r = run(&trace, p.as_mut(), SimConfig::default(), Box::new(RustSolver));
        check_result(alg, &trace, &r).unwrap();
    }
}

#[test]
fn corner_memory_saturating_jobs() {
    // Every job wants 100% of a node's memory: zero co-location possible.
    let jobs: Vec<Job> = (0..6)
        .map(|id| Job {
            id,
            submit: id as f64 * 10.0,
            tasks: 1,
            cpu_need: 0.5,
            mem: 1.0,
            proc_time: 500.0,
        })
        .collect();
    let trace = Trace { jobs, nodes: 2, cores_per_node: 4, node_mem_gb: 4.0 };
    for alg in ["GreedyPM */per/OPT=MIN/MINVT=600", "/per/OPT=MIN"] {
        let mut p = make_policy(alg, 600.0).unwrap();
        let r = run(&trace, p.as_mut(), SimConfig::default(), Box::new(RustSolver));
        check_result(alg, &trace, &r).unwrap();
    }
}

#[test]
fn corner_single_instant_burst_of_tiny_jobs() {
    let jobs: Vec<Job> = (0..20)
        .map(|id| Job {
            id,
            submit: 5.0,
            tasks: 1,
            cpu_need: 0.25,
            mem: 0.1,
            proc_time: 1.0,
        })
        .collect();
    let trace = Trace { jobs, nodes: 2, cores_per_node: 4, node_mem_gb: 4.0 };
    let mut p = make_policy("GreedyP */OPT=MIN", 600.0).unwrap();
    let r = run(&trace, p.as_mut(), SimConfig::default(), Box::new(RustSolver));
    check_result("GreedyP */OPT=MIN", &trace, &r).unwrap();
    // Bounded stretch keeps these launch-failure-sized jobs near 1.
    assert!(r.max_stretch < 3.0, "max stretch {}", r.max_stretch);
}

#[test]
fn corner_wide_job_spanning_whole_cluster() {
    let mut jobs = vec![Job {
        id: 0,
        submit: 0.0,
        tasks: 8,
        cpu_need: 1.0,
        mem: 0.9,
        proc_time: 1000.0,
    }];
    jobs.push(Job { id: 1, submit: 1.0, tasks: 8, cpu_need: 1.0, mem: 0.9, proc_time: 100.0 });
    let trace = Trace { jobs, nodes: 8, cores_per_node: 4, node_mem_gb: 4.0 };
    for alg in ["EASY", "GreedyP */OPT=MIN", "GreedyPM */per/OPT=MIN/MINVT=600"] {
        let mut p = make_policy(alg, 600.0).unwrap();
        let r = run(&trace, p.as_mut(), SimConfig::default(), Box::new(RustSolver));
        check_result(alg, &trace, &r).unwrap();
    }
}
