//! Crash-safety acceptance tests (DESIGN.md §Crash safety): killing a live
//! simulation at an arbitrary event boundary and resuming it from its
//! snapshot image must reproduce the uninterrupted run *byte for byte* —
//! the `SimResult` bit patterns, the recorded replay trace (including its
//! result digest), and the telemetry export — on every engine, across
//! dynamic-platform scenarios, with the invariant auditor armed across the
//! resume seam. Corrupt, truncated, or torn images must always surface as
//! typed errors, never panics or silently-wrong state.

use dfrs::alloc::RustSolver;
use dfrs::coordinator::grid::{self, FaultPolicy};
use dfrs::error::DfrsError;
use dfrs::scenario;
use dfrs::sched::registry::make_policy;
use dfrs::sim::{
    resume_guarded, run_guarded, snapshot, EngineKind, ResumeOverrides, RunBudget, RunOptions,
    SimConfig, SimResult,
};
use dfrs::util::failpoint;
use dfrs::workload::lublin::{generate, LublinParams};
use dfrs::workload::scale::scale_to_load;
use dfrs::workload::Trace;
use std::path::{Path, PathBuf};

const ENGINES: [EngineKind; 3] = [EngineKind::Indexed, EngineKind::Reference, EngineKind::Lazy];
const ALG: &str = "GreedyPM */per/OPT=MIN/MINVT=600";

fn small_trace(seed: u64, jobs: usize) -> Trace {
    scale_to_load(&generate(seed, jobs, &LublinParams::default()), 0.7)
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dfrs-crash-{tag}-{}", std::process::id()))
}

/// Every observable field of a [`SimResult`], as exact bit patterns.
fn digest(r: &SimResult) -> Vec<u64> {
    vec![
        r.max_stretch.to_bits(),
        r.avg_stretch.to_bits(),
        r.underutil_area.to_bits(),
        r.norm_underutil.to_bits(),
        r.gb_moved.to_bits(),
        r.gb_per_sec.to_bits(),
        r.preemptions,
        r.migrations,
        r.preempt_per_hour.to_bits(),
        r.migrate_per_hour.to_bits(),
        r.preempt_per_job.to_bits(),
        r.migrate_per_job.to_bits(),
        r.interrupted_jobs,
        r.avail_node_seconds.to_bits(),
        r.avail_utilization.to_bits(),
        r.makespan.to_bits(),
    ]
}

/// A fully-armed run: snapshots, auditor, replay-trace recording, and
/// telemetry all on. The crash-safety contract is proven against this
/// configuration, not a stripped-down one.
#[allow(clippy::too_many_arguments)]
fn run_armed(
    trace: &Trace,
    scn_name: &str,
    engine: EngineKind,
    alg: &str,
    image: &Path,
    trace_out: &Path,
    telemetry: &Path,
    budget: RunBudget,
    every_events: Option<u64>,
    every_vt: Option<f64>,
) -> Result<SimResult, DfrsError> {
    let scn = scenario::builtin(scn_name, trace).unwrap();
    let mut policy = make_policy(alg, 600.0).unwrap();
    let opts = RunOptions {
        budget,
        audit: true,
        trace_out: Some(trace_out.to_path_buf()),
        telemetry: Some(telemetry.to_path_buf()),
        snapshot: Some(snapshot::SnapshotConfig {
            path: image.to_path_buf(),
            every_events,
            every_vt,
            scenario_name: scn_name.to_string(),
            solver_name: "rust".into(),
        }),
    };
    run_guarded(
        trace,
        policy.as_mut(),
        SimConfig::default(),
        Box::new(RustSolver),
        engine,
        &scn,
        &opts,
    )
}

fn read_bytes(p: &Path) -> Vec<u8> {
    std::fs::read(p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

fn series_path(p: &Path) -> PathBuf {
    let mut s = p.as_os_str().to_os_string();
    s.push(".series.csv");
    PathBuf::from(s)
}

fn cleanup(paths: &[&Path]) {
    for p in paths {
        std::fs::remove_file(p).ok();
        std::fs::remove_file(series_path(p)).ok();
    }
}

/// Kill (via a mid-run budget trip, which leaves an emergency image at the
/// event boundary) and resume, then require byte-identity with the
/// uninterrupted armed oracle: result digest, replay trace file, telemetry
/// file and its series CSV — for all three engines across four
/// dynamic-platform scenarios, `--audit` armed on both sides of the seam.
#[test]
fn kill_and_resume_is_byte_identical_across_engines_and_scenarios() {
    let _guard = failpoint::test_lock();
    failpoint::disarm();
    let trace = small_trace(17, 36);
    for engine in ENGINES {
        for scn_name in ["failures", "drain", "burst", "chaos"] {
            let tag = format!("seam-{engine:?}-{scn_name}");
            let (img_a, out_a, tel_a) =
                (tmp(&format!("{tag}-imgA")), tmp(&format!("{tag}-outA")), tmp(&format!("{tag}-telA")));
            let (img_b, out_b, tel_b) =
                (tmp(&format!("{tag}-imgB")), tmp(&format!("{tag}-outB")), tmp(&format!("{tag}-telB")));

            // Uninterrupted oracle (armed: snapshotting changes the policy's
            // transient-cache schedule, so the oracle must be armed too).
            let oracle = run_armed(
                &trace, scn_name, engine, ALG, &img_a, &out_a, &tel_a,
                RunBudget::default(), Some(7), None,
            )
            .unwrap_or_else(|e| panic!("{tag}: oracle failed: {e}"));

            // "Kill" mid-run: the budget trips at the 23-event boundary and
            // leaves a resumable emergency image.
            let err = run_armed(
                &trace, scn_name, engine, ALG, &img_b, &out_b, &tel_b,
                RunBudget { max_events: 23, ..RunBudget::default() }, Some(7), None,
            )
            .expect_err("23 events cannot finish 36 jobs");
            assert_eq!(err.kind(), "budget_exhausted", "{tag}: {err}");
            assert!(img_b.exists(), "{tag}: the trip must leave an image");

            // Resume across the seam with a fresh budget.
            let img = snapshot::read_image(&img_b)
                .unwrap_or_else(|e| panic!("{tag}: image unreadable: {e}"));
            assert_eq!(img.loop_state.events, 23, "{tag}: image is at the kill boundary");
            let (resumed, _tel) = resume_guarded(
                &img,
                ResumeOverrides { budget: Some(RunBudget::default()), ..ResumeOverrides::default() },
            )
            .unwrap_or_else(|e| panic!("{tag}: resume failed: {e}"));

            assert_eq!(digest(&oracle), digest(&resumed), "{tag}: SimResult bits");
            assert_eq!(
                read_bytes(&out_a),
                read_bytes(&out_b),
                "{tag}: replay trace (incl. result digest) must be byte-identical"
            );
            assert_eq!(
                read_bytes(&tel_a),
                read_bytes(&tel_b),
                "{tag}: telemetry export must be byte-identical"
            );
            assert_eq!(
                read_bytes(&series_path(&tel_a)),
                read_bytes(&series_path(&tel_b)),
                "{tag}: telemetry series CSV must be byte-identical"
            );
            cleanup(&[&img_a, &out_a, &tel_a, &img_b, &out_b, &tel_b]);
        }
    }
}

/// The seam position must not matter: kill at several different event
/// boundaries (and once under a virtual-time cadence) and resume — every
/// variant lands on the same digest as the uninterrupted run. The batch
/// baseline exercises `BatchPolicy`'s snapshot/restore path too.
#[test]
fn any_kill_boundary_and_any_cadence_resumes_to_the_same_digest() {
    let _guard = failpoint::test_lock();
    failpoint::disarm();
    let trace = small_trace(29, 30);
    for alg in [ALG, "EASY"] {
        let tag0 = format!("bnd-{}", if alg == "EASY" { "easy" } else { "dfrs" });
        let (img_a, out_a, tel_a) =
            (tmp(&format!("{tag0}-imgA")), tmp(&format!("{tag0}-outA")), tmp(&format!("{tag0}-telA")));
        let oracle = run_armed(
            &trace, "failures", EngineKind::Indexed, alg, &img_a, &out_a, &tel_a,
            RunBudget::default(), Some(5), None,
        )
        .unwrap();
        for (kill_at, every_ev, every_vt) in
            [(5u64, Some(5u64), None), (17, Some(5), None), (40, None, Some(900.0))]
        {
            let tag = format!("{tag0}-k{kill_at}");
            let (img_b, out_b, tel_b) = (
                tmp(&format!("{tag}-imgB")),
                tmp(&format!("{tag}-outB")),
                tmp(&format!("{tag}-telB")),
            );
            run_armed(
                &trace, "failures", EngineKind::Indexed, alg, &img_b, &out_b, &tel_b,
                RunBudget { max_events: kill_at, ..RunBudget::default() }, every_ev, every_vt,
            )
            .expect_err("budget must trip mid-run");
            let img = snapshot::read_image(&img_b).unwrap();
            let (resumed, _) = resume_guarded(
                &img,
                ResumeOverrides { budget: Some(RunBudget::default()), ..ResumeOverrides::default() },
            )
            .unwrap_or_else(|e| panic!("{tag}: resume failed: {e}"));
            assert_eq!(digest(&oracle), digest(&resumed), "{tag}");
            assert_eq!(read_bytes(&tel_a), read_bytes(&tel_b), "{tag}: telemetry");
            cleanup(&[&img_b, &out_b, &tel_b]);
        }
        cleanup(&[&img_a, &out_a, &tel_a]);
    }
}

/// Chaos harness: a deterministic mid-event-loop abort (the `run.abort`
/// failpoint) kills the run at a seeded boundary; the emergency image it
/// leaves resumes to the uninterrupted digest.
#[test]
fn failpoint_abort_leaves_a_resumable_image() {
    let _guard = failpoint::test_lock();
    failpoint::disarm();
    let trace = small_trace(41, 30);
    let (img_a, out_a, tel_a) = (tmp("fp-imgA"), tmp("fp-outA"), tmp("fp-telA"));
    let (img_b, out_b, tel_b) = (tmp("fp-imgB"), tmp("fp-outB"), tmp("fp-telB"));
    let oracle = run_armed(
        &trace, "chaos", EngineKind::Lazy, ALG, &img_a, &out_a, &tel_a,
        RunBudget::default(), Some(6), None,
    )
    .unwrap();

    failpoint::arm("run.abort=25").unwrap();
    let err = run_armed(
        &trace, "chaos", EngineKind::Lazy, ALG, &img_b, &out_b, &tel_b,
        RunBudget::default(), Some(6), None,
    )
    .expect_err("the armed failpoint must abort the loop");
    failpoint::disarm();
    assert_eq!(err.kind(), "fail_point", "{err}");
    assert!(err.to_string().contains("run.abort"), "{err}");
    assert!(img_b.exists(), "the abort must leave an emergency image");

    let img = snapshot::read_image(&img_b).unwrap();
    let (resumed, _) = resume_guarded(&img, ResumeOverrides::default()).unwrap();
    assert_eq!(digest(&oracle), digest(&resumed));
    assert_eq!(read_bytes(&out_a), read_bytes(&out_b), "replay trace across the abort seam");
    assert_eq!(read_bytes(&tel_a), read_bytes(&tel_b), "telemetry across the abort seam");
    cleanup(&[&img_a, &out_a, &tel_a, &img_b, &out_b, &tel_b]);
}

/// Fuzz-style robustness (satellite): truncations at many byte counts and
/// single-bit flips at stepped positions must always surface as typed
/// `DfrsError`s — never a panic, never a silently-resumed wrong state.
#[test]
fn truncated_and_bitflipped_images_are_always_typed_errors() {
    let _guard = failpoint::test_lock();
    failpoint::disarm();
    let trace = small_trace(53, 24);
    let (img, out, tel) = (tmp("fuzz-img"), tmp("fuzz-out"), tmp("fuzz-tel"));
    run_armed(
        &trace, "failures", EngineKind::Indexed, ALG, &img, &out, &tel,
        RunBudget { max_events: 20, ..RunBudget::default() }, Some(4), None,
    )
    .expect_err("budget trips, leaving an image");
    let pristine = read_bytes(&img);
    assert!(snapshot::read_image(&img).is_ok(), "the pristine image must load");

    let mangled = tmp("fuzz-mangled");
    // Truncations: empty file, tiny prefixes, and every eighth of the file.
    let mut cuts = vec![0usize, 1, 2, 17];
    cuts.extend((1..8).map(|i| pristine.len() * i / 8));
    cuts.push(pristine.len() - 1);
    for cut in cuts {
        std::fs::write(&mangled, &pristine[..cut]).unwrap();
        let e = snapshot::read_image(&mangled).expect_err(&format!("truncated at {cut} bytes"));
        assert!(
            matches!(e.kind(), "snapshot_format" | "io"),
            "cut {cut}: typed error, got {e}"
        );
    }
    // Single-bit flips marched across the file (including the trailing
    // newline and the checksum record itself).
    let step = (pristine.len() / 41).max(1);
    for pos in (0..pristine.len()).step_by(step) {
        for mask in [0x01u8, 0x40] {
            let mut bytes = pristine.clone();
            bytes[pos] ^= mask;
            if bytes == pristine {
                continue;
            }
            std::fs::write(&mangled, &bytes).unwrap();
            let e = snapshot::read_image(&mangled)
                .expect_err(&format!("flip at {pos} mask {mask:#x} must not load"));
            assert!(
                matches!(e.kind(), "snapshot_format" | "io"),
                "pos {pos}: typed error, got {e}"
            );
        }
    }
    cleanup(&[&img, &out, &tel, &mangled]);
}

/// Sub-cell resume in the experiment grid: a cell killed mid-run leaves its
/// image in the campaign's `<checkpoint>.images/` directory; the retry
/// resumes from that image and must produce the same value the
/// uninterrupted cell would have — so the campaign CSV is unchanged.
#[test]
fn grid_cell_resumes_from_its_mid_run_image() {
    let _guard = failpoint::test_lock();
    failpoint::disarm();
    let trace = small_trace(61, 30);
    // Armed oracle for the cell's metric.
    let (img_o, out_o, tel_o) = (tmp("grid-imgO"), tmp("grid-outO"), tmp("grid-telO"));
    let oracle = run_armed(
        &trace, "failures", EngineKind::Indexed, ALG, &img_o, &out_o, &tel_o,
        RunBudget::default(), Some(8), None,
    )
    .unwrap();
    cleanup(&[&img_o, &out_o, &tel_o]);

    let ckpt = tmp("grid-ckpt");
    std::fs::remove_file(&ckpt).ok();
    let fp = FaultPolicy { retries: 1, checkpoint: Some(ckpt.clone()), resume: false };
    grid::prepare_checkpoint(&fp).unwrap();
    let keys = vec!["crash/failures/cell-0".to_string()];
    let outcomes = grid::run_cells(&keys, &fp, |_, ctx| {
        let img_path = ctx.image.clone().expect("checkpointed campaign provides image paths");
        if ctx.attempt == 1 {
            // First attempt dies mid-run (budget trip = injected kill); the
            // emergency image lands on the cell's CellCtx path.
            let (out_k, tel_k) = (tmp("grid-outK"), tmp("grid-telK"));
            let err = run_armed(
                &trace, "failures", EngineKind::Indexed, ALG, &img_path, &out_k, &tel_k,
                RunBudget { max_events: 21, ..RunBudget::default() }, Some(8), None,
            )
            .expect_err("the injected budget must trip");
            return Err(anyhow::anyhow!("injected kill: {err}"));
        }
        // Retry: resume from the image instead of recomputing from scratch.
        let img = snapshot::read_image(&img_path)?;
        assert_eq!(img.loop_state.events, 21, "resume starts at the kill boundary");
        let (r, _tel) = resume_guarded(
            &img,
            ResumeOverrides { budget: Some(RunBudget::default()), ..ResumeOverrides::default() },
        )?;
        Ok(vec![r.max_stretch, r.avg_stretch, r.interrupted_jobs as f64])
    })
    .unwrap();
    assert_eq!(outcomes[0].status(), "ok");
    assert_eq!(outcomes[0].attempts, 2, "killed once, resumed once");
    assert_eq!(
        outcomes[0].values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        [oracle.max_stretch, oracle.avg_stretch, oracle.interrupted_jobs as f64]
            .iter()
            .map(|v| v.to_bits())
            .collect::<Vec<_>>(),
        "the resumed cell must reproduce the uninterrupted cell's values"
    );
    // Success removes the mid-run image.
    let images_dir = {
        let mut s = ckpt.as_os_str().to_os_string();
        s.push(".images");
        PathBuf::from(s)
    };
    let leftovers: Vec<_> = std::fs::read_dir(&images_dir)
        .map(|d| d.filter_map(|e| e.ok()).collect())
        .unwrap_or_default();
    assert!(leftovers.is_empty(), "completed cells clean up their images: {leftovers:?}");
    std::fs::remove_dir_all(&images_dir).ok();
    std::fs::remove_file(&ckpt).ok();
    cleanup(&[&tmp("grid-outK"), &tmp("grid-telK")]);
}
