//! Observability walkthrough: run a batch baseline (EASY) and a DFRS
//! algorithm over the same Lublin workload with a telemetry recorder
//! installed, then compare what the two schedulers actually *did* — event
//! and preemption counters side by side, and the max/avg-stretch-so-far
//! trajectory sampled through virtual time. This is the programmatic twin
//! of `dfrs simulate --telemetry` + `dfrs report`.
//!
//! Run: `cargo run --release --example observability [-- --jobs 250 --load 0.7]`

use dfrs::alloc::RustSolver;
use dfrs::scenario::Scenario;
use dfrs::sched::registry::make_policy;
use dfrs::sim::{run_instrumented, EngineKind, RunOptions, SimConfig};
use dfrs::telemetry::{RecorderConfig, Sample, Telemetry};
use dfrs::util::cli::Args;
use dfrs::workload::lublin::{generate, LublinParams};
use dfrs::workload::scale::scale_to_load;
use dfrs::workload::Trace;

const BATCH: &str = "EASY";
const DFRS: &str = "GreedyPM */per/OPT=MIN/MINVT=600";

fn record(alg: &str, trace: &Trace) -> anyhow::Result<Telemetry> {
    let mut policy = make_policy(alg, 600.0).map_err(|e| anyhow::anyhow!("policy {alg}: {e}"))?;
    let (result, telemetry) = run_instrumented(
        trace,
        policy.as_mut(),
        SimConfig::default(),
        Box::new(RustSolver),
        EngineKind::Indexed,
        &Scenario::default(),
        &RunOptions::default(),
        RecorderConfig::default(),
    )?;
    println!(
        "{alg:<36} max-stretch {:>10.2}  avg {:>7.2}  preemptions {:>5}  migrations {:>5}",
        result.max_stretch, result.avg_stretch, result.preemptions, result.migrations
    );
    Ok(telemetry)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let jobs = args.usize_or("jobs", 250)?;
    let load = args.f64_or("load", 0.7)?;
    let seed = args.u64_or("seed", 7)?;
    let trace = scale_to_load(&generate(seed, jobs, &LublinParams::default()), load);
    println!(
        "observability: lublin seed={seed}, {jobs} jobs x {} nodes @ load {load}\n",
        trace.nodes
    );

    let batch = record(BATCH, &trace)?;
    let dfrs = record(DFRS, &trace)?;

    // Counter comparison — where the two schedulers spend their events.
    println!("\n{:<28} {:>14} {:>14}", "counter", BATCH, "DFRS");
    for name in [
        "events_total",
        "events_submission",
        "events_completion",
        "events_tick",
        "pack_probes",
        "pack_drop_restarts",
        "opportunistic_starts",
        "repack_cache_hits",
        "repack_cache_misses",
        "requeue_penalties",
    ] {
        let (b, d) = (batch.counter(name), dfrs.counter(name));
        if b > 0 || d > 0 {
            println!("{name:<28} {b:>14} {d:>14}");
        }
    }

    // Stretch trajectory — max/avg bounded stretch over completed jobs,
    // sampled on the recorder's fixed virtual-time cadence. Both runs are
    // sampled on the same cadence, so rows align until the shorter
    // makespan runs out.
    println!(
        "\n{:>10} | {:>12} {:>12} | {:>12} {:>12}",
        "t", "batch max", "batch avg", "dfrs max", "dfrs avg"
    );
    let rows = batch.samples.len().max(dfrs.samples.len());
    // ~12 evenly spaced rows keep the table readable at any trace length.
    let step = (rows / 12).max(1);
    for i in (0..rows).step_by(step) {
        let t = batch
            .samples
            .get(i)
            .or_else(|| dfrs.samples.get(i))
            .map(|s| s.t)
            .unwrap_or_default();
        let cell = |s: Option<&Sample>| match s {
            Some(s) => format!("{:>12.2} {:>12.2}", s.max_stretch_so_far, s.avg_stretch_so_far),
            None => format!("{:>12} {:>12}", "-", "-"),
        };
        println!("{t:>10.0} | {} | {}", cell(batch.samples.get(i)), cell(dfrs.samples.get(i)));
    }

    let (bm, dm) = (batch.samples.last(), dfrs.samples.last());
    if let (Some(b), Some(d)) = (bm, dm) {
        println!(
            "\nfinal: batch max-stretch-so-far {:.2} vs DFRS {:.2} — the paper's headline gap, \
             now visible as a trajectory instead of a single end-of-run number",
            b.max_stretch_so_far, d.max_stretch_so_far
        );
    }
    Ok(())
}
