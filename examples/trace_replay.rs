//! Replay a real SWF trace (Parallel Workloads Archive format) through the
//! §5.3.1 HPC2N preprocessing pipeline and compare EASY against the best
//! DFRS algorithm on it, week by week.
//!
//! With no argument, a self-generated HPC2N-like SWF file is written and
//! replayed, so the example is runnable offline; point it at a real
//! archive log (e.g. HPC2N-2002-2.2-cln.swf) to reproduce the paper's
//! real-world columns:
//!
//!   cargo run --release --example trace_replay -- --swf path/to/log.swf

use dfrs::sched::registry::make_policy;
use dfrs::sim::{run, SimConfig};
use dfrs::util::cli::Args;
use dfrs::util::stats::Summary;
use dfrs::workload::{hpc2n, scale, swf};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let path = match args.get("swf") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            // Self-generated stand-in (DESIGN.md §Substitutions): write SWF
            // bytes to disk and replay through the real loader.
            let t = hpc2n::generate(args.u64_or("seed", 3)?, args.usize_or("jobs", 1500)?);
            let p = std::env::temp_dir().join("dfrs_hpc2n_like.swf");
            std::fs::write(&p, swf::to_swf(&t))?;
            println!("no --swf given; generated HPC2N-like log at {}", p.display());
            p
        }
    };

    let full = swf::load_hpc2n(&path)?;
    println!(
        "loaded {}: {} jobs on {} nodes ({} cores, {} GB/node)",
        path.display(),
        full.jobs.len(),
        full.nodes,
        full.cores_per_node,
        full.node_mem_gb
    );

    // §5.3.1: split into week-long scenarios.
    let weeks = scale::split_segments(&full, 7.0 * 86_400.0, 20);
    println!("split into {} week-long segments (≥20 jobs each)\n", weeks.len());

    let algs = ["EASY", "GreedyPM */per/OPT=MIN/MINVT=600"];
    let mut sums: Vec<Summary> = algs.iter().map(|_| Summary::new()).collect();
    println!("{:<6} {:>6} {:>14} {:>14}", "week", "jobs", algs[0], algs[1]);
    for (w, trace) in weeks.iter().enumerate() {
        let mut row = Vec::new();
        for (alg, sum) in algs.iter().zip(sums.iter_mut()) {
            let mut p = make_policy(alg, 600.0)?;
            let r = run(trace, p.as_mut(), SimConfig::default(), Box::new(dfrs::alloc::RustSolver));
            sum.add(r.max_stretch);
            row.push(r.max_stretch);
        }
        println!("{:<6} {:>6} {:>14.1} {:>14.1}", w, trace.jobs.len(), row[0], row[1]);
    }
    println!(
        "\nmean max-stretch: {} {:.1} vs {} {:.1}",
        algs[0],
        sums[0].mean(),
        algs[1],
        sums[1].mean()
    );
    Ok(())
}
