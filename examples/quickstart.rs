//! Quickstart: generate a small synthetic workload, run the paper's
//! recommended algorithm (GreedyPM */per/OPT=MIN/MINVT=600, §6.4.2), and
//! print the headline metrics.
//!
//! Run: `cargo run --release --example quickstart`

use dfrs::sched::registry::make_policy;
use dfrs::sim::{run, SimConfig};
use dfrs::workload::lublin::{generate, LublinParams};
use dfrs::workload::scale::scale_to_load;

fn main() -> anyhow::Result<()> {
    // 1. A 128-node cluster workload from the Lublin–Feitelson model
    //    (§5.3.2), scaled to offered load 0.7.
    let trace = scale_to_load(&generate(42, 300, &LublinParams::default()), 0.7);
    println!(
        "workload: {} jobs on {} nodes, offered load {:.2}",
        trace.jobs.len(),
        trace.nodes,
        trace.offered_load()
    );

    // 2. The recommended DFRS algorithm, with the default 10-minute period.
    let alg = "GreedyPM */per/OPT=MIN/MINVT=600";
    let mut policy = make_policy(alg, 600.0)?;

    // 3. Run on the simulator. The yield solver is the AOT-compiled XLA
    //    artifact when built (`make artifacts`), else the Rust reference.
    let solver = dfrs::runtime::best_solver();
    println!("algorithm: {alg}\nsolver:    {}", solver.name());
    let r = run(&trace, policy.as_mut(), SimConfig::default(), solver);

    // 4. Report.
    println!("\nresults:");
    println!("  max bounded stretch  : {:.2}", r.max_stretch);
    println!("  avg bounded stretch  : {:.2}", r.avg_stretch);
    println!("  norm underutilization: {:.3}", r.norm_underutil);
    println!("  preemptions/job      : {:.2}", r.preempt_per_job);
    println!("  migrations/job       : {:.2}", r.migrate_per_job);
    println!("  bandwidth            : {:.3} GB/s", r.gb_per_sec);
    Ok(())
}
