//! Scenario engine demo: the same workload and algorithms under a dynamic
//! platform — node failures with repairs, maintenance drains, arrival
//! bursts and elastic capacity — compared against the static baseline.
//!
//! Run: `cargo run --release --example failures [-- --jobs 200 --load 0.7]`
//! CI smoke mode: `cargo run --example failures -- --smoke`
//!
//! Also shows the scenario *spec* path: the hand-written text format is
//! parsed, validated and run like any built-in.

use dfrs::scenario::{self, Scenario};
use dfrs::sched::registry::make_policy;
use dfrs::sim::{run_scenario, EngineKind, SimConfig};
use dfrs::util::cli::Args;
use dfrs::workload::lublin::{generate, LublinParams};
use dfrs::workload::scale::scale_to_load;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let jobs = if smoke { 60 } else { args.usize_or("jobs", 200)? };
    let load = args.f64_or("load", 0.7)?;
    let trace = scale_to_load(&generate(args.u64_or("seed", 13)?, jobs, &LublinParams::default()), load);
    println!(
        "workload: {} jobs on {} nodes, offered load {:.2}{}",
        trace.jobs.len(),
        trace.nodes,
        trace.offered_load(),
        if smoke { " (smoke mode)" } else { "" }
    );

    let algs = ["EASY", "GreedyPM */per/OPT=MIN/MINVT=600"];
    let scenarios = ["none", "failures", "drain", "burst", "elastic"];
    println!(
        "\n{:<40} {:<10} {:>11} {:>9} {:>9} {:>10}",
        "algorithm", "scenario", "max-stretch", "interrupt", "pmtn/job", "avail-util"
    );
    for alg in algs {
        for name in scenarios {
            let scn = scenario::builtin(name, &trace).map_err(anyhow::Error::msg)?;
            scn.validate(trace.nodes).map_err(anyhow::Error::msg)?;
            let mut policy = make_policy(alg, 600.0)?;
            let r = run_scenario(
                &trace,
                policy.as_mut(),
                SimConfig::default(),
                Box::new(dfrs::alloc::RustSolver),
                EngineKind::Indexed,
                &scn,
            );
            println!(
                "{:<40} {:<10} {:>11.1} {:>9} {:>9.2} {:>10.3}",
                alg, name, r.max_stretch, r.interrupted_jobs, r.preempt_per_job, r.avail_utilization
            );
        }
    }

    // The declarative text format: a morning rack outage plus a burst.
    let spec = "\
name = rack-outage
fail   node=0 at=2000 until=20000
fail   node=1 at=2000 until=20000
drain  node=2 at=1000 until=30000
burst  factor=3 from=0 until=10000
";
    let custom: Scenario = dfrs::scenario::spec::parse(spec).map_err(anyhow::Error::msg)?;
    custom.validate(trace.nodes).map_err(anyhow::Error::msg)?;
    let mut policy = make_policy("GreedyPM */per/OPT=MIN/MINVT=600", 600.0)?;
    let r = run_scenario(
        &trace,
        policy.as_mut(),
        SimConfig::default(),
        Box::new(dfrs::alloc::RustSolver),
        EngineKind::Indexed,
        &custom,
    );
    println!(
        "\nspec-file scenario {:?}: {} events, {} modulators -> max stretch {:.1}, \
         {} interruptions, avail-util {:.3}",
        custom.name,
        custom.events.len(),
        custom.arrivals.len(),
        r.max_stretch,
        r.interrupted_jobs,
        r.avail_utilization
    );
    println!(
        "\ntakeaway: DFRS absorbs platform dynamics by requeueing and re-packing;\n\
         batch scheduling pays for every disturbance with queue-wide delays."
    );
    Ok(())
}
