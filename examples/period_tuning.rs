//! The §6.4.2 trade-off in miniature: sweep the MCB8 application period and
//! watch underutilization fall while max stretch slowly rises (Figures 3-4),
//! reproducing the paper's recommendation of a period ≈10× the rescheduling
//! penalty.
//!
//! Run: `cargo run --release --example period_tuning [-- --jobs 250 --load 0.7]`

use dfrs::sched::registry::make_policy;
use dfrs::sim::{run, SimConfig};
use dfrs::util::cli::Args;
use dfrs::workload::lublin::{generate, LublinParams};
use dfrs::workload::scale::scale_to_load;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let trace = scale_to_load(
        &generate(args.u64_or("seed", 11)?, args.usize_or("jobs", 250)?, &LublinParams::default()),
        args.f64_or("load", 0.7)?,
    );
    let alg = "GreedyPM */per/OPT=MIN/MINVT=600";

    // EASY reference line (period-independent).
    let mut easy = make_policy("EASY", 600.0)?;
    let r_easy = run(&trace, easy.as_mut(), SimConfig::default(), Box::new(dfrs::alloc::RustSolver));
    println!(
        "EASY reference: max stretch {:.1}, norm underutil {:.3}\n",
        r_easy.max_stretch, r_easy.norm_underutil
    );

    println!("{:>8} {:>12} {:>12} {:>10}", "period", "max-stretch", "underutil", "GB/s");
    for period in [600.0, 1200.0, 3000.0, 6000.0, 12_000.0] {
        let mut p = make_policy(alg, period)?;
        let r = run(&trace, p.as_mut(), SimConfig::default(), Box::new(dfrs::alloc::RustSolver));
        println!(
            "{:>7.0}s {:>12.1} {:>12.3} {:>10.3}",
            period, r.max_stretch, r.norm_underutil, r.gb_per_sec
        );
    }
    println!(
        "\npaper's conclusion (§6.4.2): a period of 5-20x the 300 s penalty keeps\n\
         the stretch advantage while matching or beating EASY's utilization."
    );
    Ok(())
}
