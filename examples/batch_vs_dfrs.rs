//! End-to-end driver (the repository's headline validation, recorded in
//! EXPERIMENTS.md): run the full three-layer system — synthetic +
//! HPC2N-like workload generation, the offline LP/flow bound, batch
//! baselines and DFRS algorithms with the XLA-backed allocation — and
//! report the paper's primary metric, *degradation from bound*, showing
//! DFRS's order-of-magnitude win over batch scheduling (§6.1, Table 2).
//!
//! Run: `cargo run --release --example batch_vs_dfrs [-- --jobs 300 --traces 5 --load 0.7]`

use dfrs::bound::max_stretch_lower_bound;
use dfrs::sched::registry::make_policy;
use dfrs::sim::{run, SimConfig};
use dfrs::util::cli::Args;
use dfrs::util::stats::Summary;
use dfrs::workload::lublin::{generate, LublinParams};
use dfrs::workload::{hpc2n, scale};

const ALGS: &[&str] = &[
    "FCFS",
    "EASY",
    "Greedy */OPT=MIN",
    "GreedyP */OPT=MIN",
    "GreedyPM/per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "MCB8 */OPT=MIN/MINVT=600",
    "/per/OPT=MIN/MINVT=600",
];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let jobs = args.usize_or("jobs", 300)?;
    let traces = args.usize_or("traces", 5)?;
    let load = args.f64_or("load", 0.7)?;
    let seed = args.u64_or("seed", 7)?;

    // Trace sets: scaled synthetic + HPC2N-like weekly segments.
    let synthetic: Vec<_> = (0..traces)
        .map(|i| scale::scale_to_load(&generate(seed + i as u64, jobs, &LublinParams::default()), load))
        .collect();
    let real: Vec<_> = (0..traces).map(|i| hpc2n::generate(seed + 100 + i as u64, jobs)).collect();

    let solver_name = dfrs::runtime::best_solver().name();
    println!("end-to-end driver: {traces}x{jobs} jobs/trace, load {load}, solver={solver_name}");

    for (set_name, set) in [("scaled synthetic", &synthetic), ("hpc2n-like", &real)] {
        println!("\n=== {set_name} ===");
        // The bound is per-trace, algorithm-independent (clairvoyant LP/flow).
        let t0 = std::time::Instant::now();
        let bounds: Vec<f64> =
            set.iter().map(|t| max_stretch_lower_bound(t, 10.0, 1e-3)).collect();
        println!(
            "offline bounds: {:?} ({:.1}s)",
            bounds.iter().map(|b| (b * 10.0).round() / 10.0).collect::<Vec<_>>(),
            t0.elapsed().as_secs_f64()
        );
        println!(
            "{:<40} {:>10} {:>10} {:>10} {:>12}",
            "algorithm", "avg-deg", "max-deg", "underutil", "sim-time"
        );
        let mut batch_avg = f64::NAN;
        for alg in ALGS {
            let mut deg = Summary::new();
            let mut underutil = Summary::new();
            let t0 = std::time::Instant::now();
            for (t, b) in set.iter().zip(&bounds) {
                let mut p = make_policy(alg, 600.0)?;
                let r = run(t, p.as_mut(), SimConfig::default(), dfrs::runtime::best_solver());
                deg.add(r.max_stretch / b.max(1.0));
                underutil.add(r.norm_underutil);
            }
            if *alg == "EASY" {
                batch_avg = deg.mean();
            }
            println!(
                "{:<40} {:>10.1} {:>10.1} {:>10.3} {:>11.2}s",
                alg,
                deg.mean(),
                deg.max(),
                underutil.mean(),
                t0.elapsed().as_secs_f64()
            );
        }
        // Headline check: best DFRS vs EASY.
        let mut p = make_policy("GreedyPM */per/OPT=MIN/MINVT=600", 600.0)?;
        let mut best = Summary::new();
        for (t, b) in set.iter().zip(&bounds) {
            let r = run(t, p.as_mut(), SimConfig::default(), dfrs::runtime::best_solver());
            best.add(r.max_stretch / b.max(1.0));
        }
        println!(
            "\nheadline: EASY degradation {:.1} vs best DFRS {:.1} -> {:.0}x improvement",
            batch_avg,
            best.mean(),
            batch_avg / best.mean()
        );
    }
    Ok(())
}
